"""Serving launcher: continuous-batching engine over a registry arch.

Resident weights (default):
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --scaled --requests 10

Offloaded weights through the PIPO pipeline (models larger than device
memory; see serving/offload_engine.py).  The pipeline stays warm across
decode steps by default (cross-step preloading; --no-warm for the cold
per-step baseline), keeps a budget-sized window of layers in flight
(--preload-depth to override; docs/TUNING.md walks the sizing), and
--quant int4 streams packed INT4 weights over the offload link (~1/4
the bytes, dequant overlapped with compute):
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --scaled --offload --placement disk --pipeline performance
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --scaled --offload --quant int4
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--scaled", action="store_true")
    ap.add_argument("--b-max", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--offload", action="store_true",
                    help="stream weights from host/disk via the PIPO "
                         "pipeline instead of keeping them resident")
    ap.add_argument("--placement", default="host",
                    choices=("host", "disk"),
                    help="weight tier for --offload")
    ap.add_argument("--pipeline", default="performance",
                    choices=("performance", "memory", "sequential"),
                    help="PIPO scheduling mode for --offload")
    ap.add_argument("--quant", default=None, choices=("int4",),
                    help="stream weights as packed INT4 (--offload only); "
                         "~1/4 the link bytes, dequant overlapped on the "
                         "transfer pool")
    ap.add_argument("--no-warm", action="store_true",
                    help="disable cross-step preloading (cold per-step "
                         "pipeline, the pre-warm baseline)")
    ap.add_argument("--preload-depth", type=int, default=None,
                    metavar="D",
                    help="layers kept in flight beyond the computing one "
                         "(--offload, performance pipeline); default: "
                         "sized from the memory budget "
                         "(autoconfig.serving_preload_depth, see "
                         "docs/TUNING.md)")
    ap.add_argument("--sim-bw", type=float, default=None,
                    help="simulated link bandwidth floor in bytes/s "
                         "(deterministic transfer timing; see "
                         "docs/BENCHMARKS.md)")
    args = ap.parse_args()
    if not args.offload and (args.quant or args.no_warm
                             or args.sim_bw is not None
                             or args.preload_depth is not None):
        ap.error("--quant/--no-warm/--sim-bw/--preload-depth only apply to "
                 "--offload (the resident engine streams nothing)")

    from repro.configs import get_config, scaled_down
    from repro.serving import (OffloadedServingEngine, Request, ServingEngine)

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = scaled_down(cfg)
    if args.offload:
        eng = OffloadedServingEngine(cfg, b_max=args.b_max,
                                     max_len=args.max_len,
                                     placement=args.placement,
                                     pipeline=args.pipeline,
                                     quant=args.quant,
                                     warm=not args.no_warm,
                                     depth=args.preload_depth,
                                     sim_bw=args.sim_bw)
    else:
        eng = ServingEngine(cfg, b_max=args.b_max, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, (8 + i % 8,)).astype(np.int32),
            max_new=8))
    done = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in done)
    print(f"completed={len(done)} tokens={total} tok_s={total / dt:.1f} "
          f"stats={eng.stats}")
    if args.offload:
        rep = eng.pipeline_report()
        busy = {k: f"{v['busy_s']:.2f}s" for k, v in rep["per_kind"].items()}
        print(f"pipeline[{args.pipeline}] depth={eng.sched.depth} "
              f"compute_util={rep['compute_util']:.2f} "
              f"bubble_frac={rep['bubble_frac']:.2f} busy={busy}")
        eng.shutdown()


if __name__ == "__main__":
    main()
