"""Serving launcher: continuous-batching engine over a registry arch.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --scaled --requests 10
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--scaled", action="store_true")
    ap.add_argument("--b-max", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    from repro.configs import get_config, scaled_down
    from repro.serving import Request, ServingEngine

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = scaled_down(cfg)
    eng = ServingEngine(cfg, b_max=args.b_max, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, (8 + i % 8,)).astype(np.int32),
            max_new=8))
    done = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in done)
    print(f"completed={len(done)} tokens={total} tok_s={total / dt:.1f} "
          f"stats={eng.stats}")


if __name__ == "__main__":
    main()
