"""Sharding rules: logical parameter axes -> mesh axes, cache/batch specs,
ZeRO-style optimizer-state sharding.

Strategy (see DESIGN.md §4):
  * weights: storage-sharded over `model` on their ff/vocab/experts/heads
    dims (FSDP semantics in train/prefill — GSPMD all-gathers per layer
    inside the scan; TP semantics at decode);
  * activations: batch over ("pod","data"), sequence over `model`;
  * decode KV caches: sequence-sharded over `model` (or data+model for
    batch-1 long-context);
  * optimizer moments: params sharding + largest replicated dim over `data`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.common import Dist

# logical axis -> mesh axis (None = replicated)
AXIS_RULES = {
    "vocab": "model",
    "heads_ff": "model",
    "kv_ff": "model",
    "ff": "model",
    "experts": "model",
    "expert_ff": "data",     # ZeRO-3-style storage sharding within experts
    "heads": "model",
    "lora": None,
    "embed": None,
    "conv": None,
    None: None,
}


def make_dist(mesh: Optional[Mesh], shape: Optional[ShapeConfig] = None) -> Dist:
    if mesh is None:
        return Dist.local()
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    kv_axes = ()
    if shape is not None and shape.kind == "decode":
        dp = 1
        for a in data_axes:
            dp *= mesh.shape[a]
        if shape.global_batch % dp != 0 or shape.global_batch < dp:
            # batch can't shard (long_500k b=1): spread KV over data+model
            kv_axes = data_axes + ("model",)
        else:
            kv_axes = ("model",)
    return Dist(mesh=mesh, data_axes=data_axes, model_axis="model",
                kv_axes=kv_axes)


def _dp_size(dist: Dist) -> int:
    n = 1
    for a in dist.data_axes:
        n *= dist.mesh.shape[a]
    return n


def _batch_spec(dist: Dist, global_batch: int):
    if not dist.is_dist:
        return None
    dp = _dp_size(dist)
    if global_batch % dp == 0 and global_batch >= dp:
        return dist.data_axes if len(dist.data_axes) > 1 else dist.data_axes[0]
    return None


def param_pspecs(cfg: ModelConfig, dist: Dist):
    """NamedSharding tree matching init_params structure."""
    mesh = dist.mesh
    msize = mesh.shape["model"]

    def fn(name, pd, stacked):
        dims = []
        if stacked:
            dims.append(None)
        for size, ax in zip(pd.shape, pd.axes):
            rule = AXIS_RULES.get(ax)
            if rule and size % mesh.shape[rule] == 0 and size >= mesh.shape[rule]:
                dims.append(rule)
            else:
                dims.append(None)
        return NamedSharding(mesh, P(*dims))

    return T.map_params_tree(cfg, fn)


def cache_pspecs(cfg: ModelConfig, dist: Dist, global_batch: int,
                 cache_len: int, enc_len=None):
    """NamedSharding tree matching cache_struct."""
    mesh = dist.mesh
    struct, kinds = T.cache_struct(cfg, global_batch, cache_len, enc_len)
    b_spec = _batch_spec(dist, global_batch)
    kv = dist.kv_shard_axes or ("model",)
    kv_el = kv if len(kv) > 1 else kv[0]
    # when KV spans data axes too, batch must be unsharded
    b_kv = None if any(a in kv for a in dist.data_axes) else b_spec
    msize = mesh.shape["model"]

    def spec_for(kind, nd, stacked, shape):
        lead = (None,) if stacked else ()
        if kind == "kv":
            seq = shape[len(lead) + 1]
            kv_ok = kv_el if seq % dist.kv_shards() == 0 else None
            rest = (None,) * (nd - len(lead) - 2)
            return P(*lead, b_kv, kv_ok, *rest)
        if kind == "state":
            H = shape[len(lead) + 1]
            h_ax = "model" if H % msize == 0 else None
            rest = (None,) * (nd - len(lead) - 2)
            return P(*lead, b_spec, h_ax, *rest)
        rest = (None,) * (nd - len(lead) - 1)
        return P(*lead, b_spec, *rest)

    def walk(struct_sub, kinds_sub, stacked):
        return {k: NamedSharding(mesh, spec_for(kinds_sub[k], len(s.shape),
                                                stacked, s.shape))
                for k, s in struct_sub.items()}

    pat = tuple(walk(s, kk, True) for s, kk in
                zip(struct["pat"], kinds["pat"]))
    rem = tuple(walk(s, kk, False) for s, kk in
                zip(struct["rem"], kinds["rem"]))
    return {"pat": pat, "rem": rem}


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, dist: Dist,
                 enc_pad: int = 0):
    mesh = dist.mesh
    b_spec = _batch_spec(dist, shape.global_batch)
    seq_ax = "model" if shape.seq_len % mesh.shape["model"] == 0 else None
    ns = lambda *dims: NamedSharding(mesh, P(*dims))
    if shape.kind in ("train", "prefill"):
        out = {}
        if shape.kind == "train":
            out["labels"] = ns(b_spec, seq_ax)
        if cfg.frontend == "embeds" and not cfg.enc_dec:
            out["embeds"] = ns(b_spec, seq_ax, None)
        else:
            out["tokens"] = ns(b_spec, seq_ax)
        if cfg.enc_dec:
            out["enc_embeds"] = ns(b_spec, "model", None)
        return out
    return {"token": ns(b_spec, None), "pos": ns()}


def zero_pspecs(cfg: ModelConfig, dist: Dist):
    """Optimizer-moment sharding: param spec + largest remaining replicated
    dim additionally sharded over `data` (ZeRO-1-flavored).  Needed to fit
    fp32 moments of 400-700B models on 256 chips."""
    mesh = dist.mesh
    dsize = mesh.shape["data"]

    def fn(name, pd, stacked):
        dims = [None] if stacked else []
        shape = pd.shape
        for size, ax in zip(shape, pd.axes):
            rule = AXIS_RULES.get(ax)
            if rule and size % mesh.shape[rule] == 0 and size >= mesh.shape[rule]:
                dims.append(rule)
            else:
                dims.append(None)
        # extra data-axis sharding on the largest replicated dim
        best, best_size = -1, 0
        off = 1 if stacked else 0
        for i, size in enumerate(shape):
            if dims[i + off] is None and size % dsize == 0 and size > best_size:
                best, best_size = i + off, size
        if best >= 0:
            dims[best] = "data"
        return NamedSharding(mesh, P(*dims))

    ptree = T.map_params_tree(cfg, fn)
    return {"m": ptree, "v": jax.tree.map(lambda x: x, ptree),
            "step": NamedSharding(mesh, P())}


def opt_struct(cfg: ModelConfig):
    ps = T.param_struct(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {"m": jax.tree.map(f32, ps), "v": jax.tree.map(f32, ps),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def adafactor_struct(cfg: ModelConfig, opt):
    """eval_shape'd Adafactor state structure."""
    ps = T.param_struct(cfg)
    return jax.eval_shape(opt.init, ps)


def adafactor_pspecs(cfg: ModelConfig, dist: Dist, opt):
    """Shardings for Adafactor state, derived from param specs: momentum
    mirrors the param; vr drops the last dim; vc drops the second-to-last."""
    mesh = dist.mesh

    def dims_for(pd, stacked):
        dims = [None] if stacked else []
        for size, ax in zip(pd.shape, pd.axes):
            rule = AXIS_RULES.get(ax)
            if rule and size % mesh.shape[rule] == 0 and size >= mesh.shape[rule]:
                dims.append(rule)
            else:
                dims.append(None)
        return dims

    def fn(name, pd, stacked):
        dims = dims_for(pd, stacked)
        full_shape = ((1,) + pd.shape) if stacked else pd.shape
        st = {}
        if opt.b1:
            st["m"] = NamedSharding(mesh, P(*dims))
        if len(full_shape) >= 2:
            st["vr"] = NamedSharding(mesh, P(*dims[:-1]))
            st["vc"] = NamedSharding(mesh, P(*(dims[:-2] + dims[-1:])))
        else:
            st["v"] = NamedSharding(mesh, P(*dims))
        return st

    return {"s": T.map_params_tree(cfg, fn),
            "step": NamedSharding(mesh, P())}


def replicate(dist: Dist, tree):
    """NamedSharding tree: everything replicated (for small trees)."""
    ns = NamedSharding(dist.mesh, P())
    return jax.tree.map(lambda _: ns, tree)
