"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Single pod: (16, 16) = 256 chips, ("data", "model").
Multi-pod: (2, 16, 16) = 512 chips, ("pod", "data", "model") — `pod` is an
outer data-parallel axis (DCN between pods, ICI inside).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, model: int = 4, data: int = 2):
    """Small mesh for subprocess tests (8 fake devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def stage_devices(n_stages: int):
    """One device per pipeline-parallel stage, round-robin over the
    local devices — on a single-device box every stage maps to device 0
    and the activation handoff degenerates to an on-device no-op, so
    the staged engine runs (and is testable) anywhere."""
    devs = jax.devices()
    return [devs[s % len(devs)] for s in range(max(1, int(n_stages)))]
