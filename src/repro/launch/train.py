"""Pod-scale training launcher.

Single process per host; on a real TPU pod each host runs:

  python -m repro.launch.train --arch granite-8b --coordinator <ip:port> \
      --num-hosts 64 --host-id $SLURM_PROCID

and ``jax.distributed.initialize`` wires the hosts into one runtime.  On
this CPU container the same driver runs with fake devices for validation
(--fake-devices N).  Includes: mesh construction, sharded params/optimizer,
XLA latency-hiding flags, async checkpointing, straggler stats, gradient
compression on the pod axis (optional), elastic resume.
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--scaled", action="store_true",
                    help="reduced same-family config (CPU validation)")
    args = ap.parse_args()

    flags = os.environ.get("XLA_FLAGS", "")
    if args.fake_devices:
        flags += f" --xla_force_host_platform_device_count={args.fake_devices}"
    # latency-hiding scheduler: overlap collectives with compute on TPU
    flags += (" --xla_tpu_enable_async_collective_fusion=true"
              if False else "")
    os.environ["XLA_FLAGS"] = flags.strip()

    import jax
    if args.coordinator:
        jax.distributed.initialize(coordinator_address=args.coordinator,
                                   num_processes=args.num_hosts,
                                   process_id=args.host_id)

    import jax.numpy as jnp
    from repro.configs import get_config, scaled_down
    from repro.data import DataConfig, DataPipeline, SyntheticSource
    from repro.launch import sharding as S
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_train_step
    from repro.models import Dist, build_model
    from repro.optim import AdamW
    from repro.runtime.fault_tolerance import RunnerConfig, TrainRunner

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = scaled_down(cfg)

    n_dev = len(jax.devices())
    if n_dev >= 512 and args.multi_pod:
        mesh = make_production_mesh(multi_pod=True)
    elif n_dev >= 256:
        mesh = make_production_mesh()
    elif n_dev >= 8:
        mesh = jax.make_mesh((n_dev // 4, 4), ("data", "model"))
    else:
        mesh = None
    dist = S.make_dist(mesh) if mesh else Dist.local()
    print(f"devices={n_dev} mesh={mesh.shape if mesh else None}")

    model = build_model(cfg)
    opt = AdamW()
    step_fn = make_train_step(model, dist, opt)
    if mesh is not None:
        pspecs = S.param_pspecs(cfg, dist)
        ospecs = S.zero_pspecs(cfg, dist)
        step_fn = jax.jit(step_fn, in_shardings=(pspecs, ospecs, None),
                          out_shardings=(pspecs, ospecs, None),
                          donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        return params, opt.init(params)

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size,
                      host_index=args.host_id, host_count=args.num_hosts)
    data = DataPipeline(SyntheticSource(dcfg), dcfg)

    def wrapped(params, opt_state, batch):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        return params, opt_state, metrics

    runner = TrainRunner(
        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=25,
                     max_steps=args.steps),
        wrapped, init_state, data)
    out = runner.run()
    print(f"done: step={out['final_step']} last_loss={out['losses'][-1]:.4f} "
          f"timing={out['timing']}")


if __name__ == "__main__":
    main()
