import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/roofline terms.

THE TWO LINES ABOVE MUST STAY FIRST: jax locks the device count at first
init, and the 512 placeholder devices exist only for this entry point —
tests and benches must see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Serving-plan dry-run (--serving): resolve an ``EngineSpec`` per arch
against the consumer-device budget and print the materialized plan —
engine dispatch, placement, preload depth, each with its provenance —
without lowering anything.  With a single --arch and --scaled it also
BUILDS the engine through ``create_engine(plan)`` and serves one
request (the end-to-end plan smoke):
  PYTHONPATH=src python -m repro.launch.dryrun --serving --all
  PYTHONPATH=src python -m repro.launch.dryrun --serving \
      --arch tinyllama-1.1b --scaled

Trace-replay what-if sweep (--replay): predict step time + link bytes
per (depth, quant, kv-mode) knob point from a recorded trace, offline
(``core.replay``; see docs/TUNING.md):
  PYTHONPATH=src python -m repro.launch.dryrun \
      --replay tests/fixtures/trace_warm_d1.json
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED, SHAPES, get_config, get_shape,
                           shape_applicable)
from repro.launch import sharding as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import transformer as T
from repro.models.model import build_model
from repro.optim import AdamW
from repro.optim.adafactor import Adafactor
from repro.roofline.analysis import (HW, analyze_hlo, f32_shadow_bytes,
                                     model_flops, roofline_report)


def _enc_pad(cfg, mesh):
    """Pad encoder frames to a model-axis-divisible length (whisper stub)."""
    if not cfg.enc_dec:
        return 0
    m = mesh.shape["model"]
    return ((cfg.encoder_seq_len + m - 1) // m) * m


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "base"):
    """Build + lower + compile one cell; returns (compiled, meta)."""
    import dataclasses
    cfg = get_config(arch)
    if variant == "w4":
        # beyond-paper variant: PIPO's INT4 weights at pod scale; dequant
        # VREG-fused (kernels/int4_matmul.py), packed bytes cross HBM.
        cfg = dataclasses.replace(cfg, quant_weights=True)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = S.make_dist(mesh, shape)
    model = build_model(cfg)
    enc_pad = _enc_pad(cfg, mesh)

    pspecs = S.param_pspecs(cfg, dist)
    pstruct = T.param_struct(cfg)
    bspecs = S.batch_pspecs(cfg, shape, dist, enc_pad)
    bstruct = model.input_struct(shape, enc_pad)

    if shape.kind == "train":
        # fp32 Adam moments don't fit >60B models on a pod; switch to
        # factored second moments + bf16 momentum (see optim/adafactor.py).
        if cfg.param_count() > 60e9:
            opt = Adafactor()
            ostruct = S.adafactor_struct(cfg, opt)
            ospecs = S.adafactor_pspecs(cfg, dist, opt)
        else:
            opt = AdamW()
            ostruct = S.opt_struct(cfg)
            ospecs = S.zero_pspecs(cfg, dist)
        step = make_train_step(model, dist, opt)
        fn = jax.jit(step,
                     in_shardings=(pspecs, ospecs, bspecs),
                     out_shardings=(pspecs, ospecs, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(pstruct, ostruct, bstruct)
    elif shape.kind == "prefill":
        step = make_prefill_step(model, dist, cache_len=shape.seq_len)
        cspecs = S.cache_pspecs(cfg, dist, shape.global_batch,
                                shape.seq_len, enc_pad or None)
        tok_spec = S.batch_pspecs(cfg, SHAPES["decode_32k"], dist)["token"]
        fn = jax.jit(step, in_shardings=(pspecs, bspecs),
                     out_shardings=(None, cspecs))
        lowered = fn.lower(pstruct, bstruct)
    else:  # decode
        step = make_decode_step(model, dist)
        cstruct, _ = model.cache_struct(shape.global_batch, shape.seq_len,
                                        enc_pad or None)
        cspecs = S.cache_pspecs(cfg, dist, shape.global_batch,
                                shape.seq_len, enc_pad or None)
        fn = jax.jit(step, in_shardings=(pspecs, bspecs, cspecs),
                     out_shardings=(None, cspecs), donate_argnums=(2,))
        lowered = fn.lower(pstruct, bstruct, cstruct)

    compiled = lowered.compile()
    return compiled, dict(cfg=cfg, shape=shape, mesh=mesh, variant=variant)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             variant: str = "base") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "variant": variant}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        row.update(status="skip", reason=why)
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}_{shape_name}_{mesh_tag}_{variant}.json"
         ).write_text(json.dumps(row, indent=1))
        return row
    t0 = time.time()
    try:
        compiled, meta = lower_cell(arch, shape_name, multi_pod, variant)
    except Exception as e:
        row.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
        return row
    n_dev = 512 if multi_pod else 256
    mem = compiled.memory_analysis()
    txt = compiled.as_text()
    acc = analyze_hlo(txt, total_devices=n_dev)
    rep = roofline_report(acc)
    mf = model_flops(cfg, shape)
    hlo_flops_total = acc["flops"] * n_dev
    raw_bytes = (getattr(mem, "temp_size_in_bytes", 0)
                 + getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0)
                 - getattr(mem, "alias_size_in_bytes", 0))
    shadow = f32_shadow_bytes(txt)
    row.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        devices=n_dev,
        bytes_per_device=raw_bytes,
        # XLA:CPU materializes f32 copies of bf16 dot operands (native on
        # the MXU) — subtracting them approximates the TPU-resident bytes.
        f32_shadow_bytes=shadow,
        tpu_bytes_per_device=max(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0),
            raw_bytes - shadow),
        temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
        arg_bytes=getattr(mem, "argument_size_in_bytes", 0),
        out_bytes=getattr(mem, "output_size_in_bytes", 0),
        alias_bytes=getattr(mem, "alias_size_in_bytes", 0),
        model_flops_total=mf,
        hlo_flops_per_dev=acc["flops"],
        flops_useful_ratio=(mf / hlo_flops_total) if hlo_flops_total else 0.0,
        **{k: rep[k] for k in ("t_compute_s", "t_memory_s",
                               "t_memory_cpu_cast_s", "t_collective_s",
                               "bottleneck", "t_bound_s", "hbm_bytes",
                               "ici_bytes", "dcn_bytes", "coll_count")},
        coll_breakdown={k: v for k, v in acc.items()
                        if k.startswith("coll_") and k != "coll_count"},
    )
    # roofline fraction: time at the bound vs sum of the three terms if
    # perfectly overlapped -> how close the dominant term is to the total
    tot = rep["t_compute_s"] + rep["t_memory_s"] + rep["t_collective_s"]
    row["roofline_fraction"] = rep["t_bound_s"] / tot if tot else 0.0
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch}_{shape_name}_{mesh_tag}_{variant}.json"
    (out_dir / fname).write_text(json.dumps(row, indent=1, default=str))
    return row


def replay_dryrun(path: str):
    """Offline what-if table over a recorded trace (``--replay``): load
    the ``Trace.to_json`` dump, then sweep the knobs through the
    ``core.replay`` simulator — preload depth x weight/KV precision —
    printing the predicted steady step time and per-step link volume of
    every point.  No model build, no hardware: capacity planning from a
    single recording."""
    from repro.core.replay import ReplayKnobs, replay
    from repro.core.tasks import Trace

    tr = Trace.from_json(Path(path).read_text())
    m = tr.meta
    bw = m.get("sim_bw")
    print(f"[TRACE] {path}: arch={m.get('arch', '?')} "
          f"mode={m.get('mode', '?')} warm={m.get('warm', '?')} "
          f"depth={m.get('depth', '?')} quant={m.get('quant') or 'fp32'} "
          f"kv={m.get('kv_mode') or 'fp32'} "
          f"sim_bw={f'{bw / 1e9:.2f}GB/s' if bw else 'n/a'} "
          f"events={len(tr.events())}")
    base = replay(tr).steady_step_s         # knobs exactly as recorded
    print(f"{'depth':>5s} {'weights':>8s} {'kv':>5s} {'step_ms':>8s} "
          f"{'link_MB/step':>12s} {'vs_recorded':>11s}")
    for depth in (1, 2, 3, 4):
        for wq, kv in ((None, None), ("int4", None), ("int4", "int4")):
            res = replay(tr, ReplayKnobs(depth=depth, quant=wq, kv_mode=kv))
            b = res.bytes_by_kind
            link_mb = (b["weight_load"] + b["kv_load"] + b["kv_save"]) \
                / max(1, len(res.step_times_s)) / 2**20
            print(f"{depth:5d} {wq or 'rec':>8s} {kv or 'rec':>5s} "
                  f"{res.steady_step_s * 1e3:8.2f} {link_mb:12.2f} "
                  f"{base / max(1e-12, res.steady_step_s):10.2f}x")


def serving_dryrun(arch, scaled: bool, run_all: bool, stages=None):
    """Resolve serving plans through the EngineSpec API.  Per arch: one
    plan row (engine/placement/depth + provenance; with ``--stages`` a
    [STG] row per pipeline stage showing its layer slice, preload depth
    and share of the split device budget).  Single-arch scaled mode
    additionally builds the engine via ``create_engine(plan)`` and
    serves one request — the whole spec -> plan -> engine path, live."""
    import numpy as np

    from repro.configs import list_archs
    from repro.serving.spec import EngineSpec, create_engine

    archs = sorted(list_archs()) if run_all or arch is None else [arch]
    plans = []
    for a in archs:
        plan = EngineSpec(arch=a, scaled=scaled, b_max=4, max_len=256,
                          stages=stages).resolve()
        plans.append(plan)
        stg = f" stages={plan.stages}" if plan.stages > 1 else ""
        print(f"[PLAN] {a:26s} engine={plan.engine:9s} "
              f"placement={plan.placement:6s} depth={plan.depth} "
              f"quant={plan.quant or 'fp32'} "
              f"kv={plan.kv_mode or 'n/a'}{stg}")
        for sp in plan.stage_plan:
            print(f"  [STG] stage {sp.stage}: layers "
                  f"[{sp.layer_lo}, {sp.layer_hi}) depth={sp.depth} "
                  f"device_budget={sp.device_budget / 2**30:.2f}GiB")
        for fld, why in sorted(plan.provenance.items()):
            print(f"        {fld:12s} {why}")
    if len(plans) == 1 and scaled:
        plan = plans[0]
        eng = create_engine(plan)
        from repro.serving import Request
        prompt = np.random.default_rng(0).integers(
            0, eng.cfg.vocab_size, (8,)).astype(np.int32)
        eng.submit(Request(rid=0, prompt=prompt, max_new=4))
        done = eng.run()
        eng.shutdown()
        print(f"[SMOKE] {plan.arch}: engine={type(eng).__name__} "
              f"served 1 request, {len(done[0].out)} tokens")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--serving", action="store_true",
                    help="resolve EngineSpec serving plans (per arch) "
                         "instead of lowering mesh cells; with a single "
                         "--arch and --scaled also builds the engine via "
                         "create_engine(plan) and serves one request")
    ap.add_argument("--scaled", action="store_true",
                    help="(--serving) resolve/build the scaled smoke "
                         "config instead of the full-size one")
    ap.add_argument("--stages", type=int, default=None, metavar="N",
                    help="(--serving) resolve with N pipeline-parallel "
                         "stages: the plan rows grow one [STG] line per "
                         "stage (layer slice, per-stage depth, 1/N device "
                         "budget); archs that can't stage record the "
                         "drop in provenance")
    ap.add_argument("--replay", metavar="TRACE_JSON", default=None,
                    help="offline knob sweep over a recorded trace "
                         "(Trace.to_json dump): predicted steady step "
                         "time + link bytes per (depth, quant, kv-mode) "
                         "point via core.replay — no model build, no "
                         "hardware (see docs/TUNING.md)")
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.replay:
        replay_dryrun(args.replay)
        return

    if args.serving:
        serving_dryrun(args.arch, args.scaled, args.all, stages=args.stages)
        return

    cells = []
    if args.all:
        for a in sorted(ASSIGNED):
            for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    n_err = 0
    for arch, shape in cells:
        row = run_cell(arch, shape, args.multi_pod, out_dir, args.variant)
        if row["status"] == "ok":
            print(f"[OK ] {arch:26s} {shape:12s} {row['mesh']:10s} "
                  f"compile={row['compile_s']:6.1f}s "
                  f"mem/dev={row['bytes_per_device']/2**30:6.2f}GiB "
                  f"tpu~{row['tpu_bytes_per_device']/2**30:6.2f}GiB "
                  f"bound={row['bottleneck']:10s} t={row['t_bound_s']:.4f}s "
                  f"frac={row['roofline_fraction']:.2f}")
        elif row["status"] == "skip":
            print(f"[SKIP] {arch:26s} {shape:12s} {row['reason']}")
        else:
            n_err += 1
            print(f"[ERR ] {arch:26s} {shape:12s} {row['error']}")
    if n_err:
        raise SystemExit(f"{n_err} cells failed")


if __name__ == "__main__":
    main()
